"""Vertex-granular residual push engine (`repro.engine.push`) — PR tentpole.

The load-bearing contract: `solve(algo, engine="push")` resolves exactly the
fixpoint `run_async_block` resolves — **bitwise** for the lattice semirings
(quiescence pins the monotone closure), within stopping tolerance for the
sum semirings — cold or warm, jax or pallas backend, for any bucket count.
Plus: the `engine="auto"` frontier-size router (both arms, knob dropping,
transfer-guard compatibility), `run_incremental(engine="push")` sparse delta
absorption with work proportional to the touched neighborhood, the
`out_closure`/`touched_vertices(closure=)` helper semantics, push_stats
accounting, option validation, and the GraphServer push-absorption path.
"""
import types

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.engine import (
    get_algorithm,
    multi_source_sssp,
    personalized_pagerank,
    remake,
    run_async_block,
    run_incremental,
    run_push,
)
from repro.engine import push as push_mod
from repro.engine.api import (
    EngineOptionsError,
    EngineUnsupportedError,
    solve,
)
from repro.engine.push import estimate_frontier_fraction
from repro.graphs import generators as gen
from repro.graphs.delta import GraphDelta, out_closure, random_delta
from repro.graphs.graph import Graph
from repro.serving import GraphServer

BS = 64
LATTICE = ["sssp", "bfs", "cc", "sswp", "reachability"]
SUM = ["pagerank", "katz", "php", "adsorption"]


@pytest.fixture(scope="module")
def graphs():
    g = gen.scrambled(gen.powerlaw_cluster(400, 4, p=0.4, seed=1), seed=9)
    # weights <= 1 keep the sum family contractive, so the same weighted
    # graph can serve sssp/sswp AND weighted-sum sanity runs
    gw = gen.with_random_weights(g, lo=0.1, hi=1.0, seed=2)
    return g, gw


def _algo(name, g, gw, **kw):
    graph = gw if name in ("sssp", "sswp", "ms_sssp") else g
    return get_algorithm(name, graph, **kw)


# ---------------------------------------------------------------------------
# equivalence with the sweep engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("name", LATTICE)
def test_lattice_cold_bitwise_equals_async_block(name, backend, graphs):
    g, gw = graphs
    algo = _algo(name, g, gw)
    r = solve(algo, engine="push", backend=backend)
    ref = run_async_block(algo, bs=BS)
    assert r.converged
    np.testing.assert_array_equal(np.asarray(r.x), np.asarray(ref.x))


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("name", SUM)
def test_sum_cold_within_eps_of_async_block(name, backend, graphs):
    g, gw = graphs
    algo = _algo(name, g, gw)
    r = solve(algo, engine="push", backend=backend)
    ref = run_async_block(algo, bs=BS)
    assert r.converged
    # push maintains r incrementally (r -= push; r += scatter), so hub rows
    # drift by float accumulation-order noise on top of the eps stopping rule
    np.testing.assert_allclose(
        np.asarray(r.x), np.asarray(ref.x), atol=20 * algo.eps, rtol=1e-5
    )


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_batched_columns_ms_sssp_bitwise(backend, graphs):
    _, gw = graphs
    algo = multi_source_sssp(gw, sources=[0, 42, 99])
    r = solve(algo, engine="push", backend=backend)
    ref = run_async_block(algo, bs=BS)
    assert r.x.shape == (gw.n, 3) and bool(r.col_converged.all())
    np.testing.assert_array_equal(np.asarray(r.x), np.asarray(ref.x))


def test_batched_columns_ppr_per_column_freeze(graphs):
    """Converged columns freeze out of the push: each column of a batched
    run equals its solo run within eps even when round counts diverge."""
    g, _ = graphs
    seeds = [3, 17, 40]
    algo = personalized_pagerank(g, seeds=seeds)
    r = solve(algo, engine="push")
    assert r.converged and r.x.shape == (g.n, 3)
    for j, s in enumerate(seeds):
        solo = solve(personalized_pagerank(g, seeds=[s]), engine="push")
        np.testing.assert_allclose(
            r.x[:, j], solo.x, atol=20 * algo.eps, rtol=1e-5
        )


@given(st.integers(10, 120), st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_hypothesis_sssp_push_bitwise(n, seed):
    g = gen.with_random_weights(
        gen.erdos_renyi(n, 3.0, seed=seed), lo=0.1, hi=1.0, seed=seed
    )
    algo = get_algorithm("sssp", g, source=seed % n)
    r = solve(algo, engine="push")
    ref = run_async_block(algo, bs=32)
    np.testing.assert_array_equal(np.asarray(r.x), np.asarray(ref.x))


@given(st.integers(10, 120), st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_hypothesis_pagerank_push_within_eps(n, seed):
    algo = get_algorithm("pagerank", gen.erdos_renyi(n, 3.0, seed=seed))
    r = solve(algo, engine="push")
    ref = run_async_block(algo, bs=32)
    np.testing.assert_allclose(
        np.asarray(r.x), np.asarray(ref.x), atol=5 * algo.eps, rtol=1e-5
    )


@pytest.mark.parametrize("buckets", [1, 3, 8])
def test_pallas_bucket_count_does_not_change_answer(buckets, graphs):
    _, gw = graphs
    algo = get_algorithm("sssp", gw, source=0)
    r = solve(algo, engine="push", backend="pallas", buckets=buckets)
    ref = run_async_block(algo, bs=BS)
    np.testing.assert_array_equal(np.asarray(r.x), np.asarray(ref.x))


# ---------------------------------------------------------------------------
# warm starts & incremental delta absorption (the killer application)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_incremental_push_sssp_bitwise_and_sparse(backend, graphs):
    _, gw = graphs
    algo = get_algorithm("sssp", gw, source=0)
    prior = run_async_block(algo, bs=BS)
    delta = random_delta(gw, frac_add=0.005, seed=3)
    g2 = delta.apply(gw)
    algo2 = remake(algo, g2)
    warm = run_incremental(algo2, algo, prior, engine="push", backend=backend)
    cold = run_async_block(algo2, bs=BS)
    np.testing.assert_array_equal(np.asarray(warm.x), np.asarray(cold.x))


def test_incremental_push_touches_neighborhood_not_graph(graphs):
    """A 10-edge delta's push absorption does work proportional to the
    touched neighborhood: far fewer swept-vertex relaxations than the block
    engine's rounds * n, and a strict minority of vertices touched."""
    _, gw = graphs
    algo = get_algorithm("sssp", gw, source=0)
    prior = run_async_block(algo, bs=BS)
    rng = np.random.default_rng(7)
    src = rng.integers(0, gw.n, 10).astype(np.int32)
    dst = rng.integers(0, gw.n, 10).astype(np.int32)
    keep = src != dst
    delta = GraphDelta(add_src=src[keep], add_dst=dst[keep],
                       add_w=np.full(int(keep.sum()), 0.2, np.float32))
    g2 = delta.apply(gw)
    algo2 = remake(algo, g2)
    warm_push = run_incremental(algo2, algo, prior, engine="push")
    warm_block = run_incremental(algo2, algo, prior, bs=BS)
    cold = run_async_block(algo2, bs=BS)
    np.testing.assert_array_equal(np.asarray(warm_push.x), np.asarray(cold.x))
    stats = warm_push.push_stats
    assert stats is not None
    # swept-vertex work: push settles `pushed` vertices total; the block
    # engine revisits all n every round
    assert stats["pushed"] <= 0.2 * warm_block.rounds * gw.n
    assert stats["touched_fraction"] < 0.5


def test_incremental_push_pagerank_matches_cold(graphs):
    g, _ = graphs
    algo = get_algorithm("pagerank", g)
    prior = run_async_block(algo, bs=BS)
    delta = random_delta(g, frac_add=0.01, seed=5)
    g2 = delta.apply(g)
    algo2 = remake(algo, g2)
    warm = run_incremental(algo2, algo, prior, engine="push")
    cold = run_async_block(algo2, bs=BS)
    np.testing.assert_allclose(
        np.asarray(warm.x), np.asarray(cold.x), atol=10 * algo.eps, rtol=1e-5
    )


def test_warm_restart_from_converged_state_is_noop(graphs):
    _, gw = graphs
    algo = get_algorithm("sssp", gw, source=0)
    prior = run_async_block(algo, bs=BS)
    r = solve(algo, engine="push", x_init=prior.x)
    np.testing.assert_array_equal(np.asarray(r.x), np.asarray(prior.x))
    assert r.push_stats["pushed"] == 0


# ---------------------------------------------------------------------------
# frontier estimation & the auto router
# ---------------------------------------------------------------------------

def test_estimate_frontier_fraction_regimes(graphs):
    g, gw = graphs
    # cold pagerank: every vertex carries a supra-eps teleport residual
    assert estimate_frontier_fraction(get_algorithm("pagerank", g)) == 1.0
    # cold sssp: only the source holds a pending candidate
    sssp = get_algorithm("sssp", gw, source=0)
    assert estimate_frontier_fraction(sssp) == pytest.approx(1 / gw.n)
    # a converged warm start has nothing pending
    prior = run_async_block(sssp, bs=BS)
    assert estimate_frontier_fraction(sssp, x_init=np.asarray(prior.x)) == 0.0
    # cold max-semiring workloads must establish every inert 0 -> dense
    assert estimate_frontier_fraction(
        get_algorithm("reachability", g, source=0)) == 1.0


def test_auto_routes_sparse_frontier_to_push(graphs):
    g, _ = graphs
    algo = personalized_pagerank(g, seeds=[5])
    r = solve(algo, engine="auto")
    assert r.push_stats is not None  # the push arm ran
    ref = run_async_block(algo, bs=BS)
    np.testing.assert_allclose(
        np.asarray(r.x), np.asarray(ref.x), atol=20 * algo.eps, rtol=1e-5
    )


def test_auto_routes_dense_frontier_to_sweep(graphs):
    g, _ = graphs
    r = solve(get_algorithm("pagerank", g), engine="auto")
    assert r.push_stats is None and r.converged


def test_auto_threshold_zero_never_pushes(graphs):
    g, _ = graphs
    algo = personalized_pagerank(g, seeds=[5])
    r = solve(algo, engine="auto", push_threshold=0.0)
    assert r.push_stats is None and r.converged


def test_auto_drops_sweep_knobs_when_push_wins(graphs):
    """The router's contract is 'same answer, engine's choice of work':
    sweep-batching and Aitken knobs are dropped on the push route, not
    rejected."""
    g, _ = graphs
    algo = personalized_pagerank(g, seeds=[5])
    r = solve(algo, engine="auto", extrapolate_every=4)
    assert r.push_stats is not None and r.converged


@pytest.mark.parametrize("engine", ["push", "auto"])
def test_push_and_router_under_transfer_guard(engine, graphs):
    g, _ = graphs
    algo = personalized_pagerank(g, seeds=[5])
    r = solve(algo, engine=engine, transfer_guard="disallow")
    assert r.converged and r.push_stats is not None


def test_push_pallas_under_transfer_guard(graphs):
    _, gw = graphs
    algo = get_algorithm("sssp", gw, source=0)
    r = solve(algo, engine="push", backend="pallas",
              transfer_guard="disallow")
    assert r.converged


# ---------------------------------------------------------------------------
# eps_vec / beta
# ---------------------------------------------------------------------------

def test_beta_one_is_uniform_eps(graphs):
    g, _ = graphs
    algo = get_algorithm("pagerank", g)
    np.testing.assert_array_equal(
        push_mod._eps_vec(algo, 1.0), np.full(g.n, algo.eps, np.float32)
    )


def test_beta_below_one_pushes_less_and_stays_close(graphs):
    g, _ = graphs
    algo = personalized_pagerank(g, seeds=[5])
    exact = solve(algo, engine="push", beta=1.0)
    approx = solve(algo, engine="push", beta=0.5)
    assert approx.converged
    assert approx.push_stats["pushed"] <= exact.push_stats["pushed"]
    # degree-normalized thresholds loosen per-vertex stopping by at most
    # outdeg^(1-beta); the fixpoint error stays within that envelope
    deg = Graph(algo.n, algo.src, algo.dst, algo.w).out_degrees()
    envelope = 30 * algo.eps * float(np.sqrt(np.maximum(deg, 1).max()))
    np.testing.assert_allclose(
        np.asarray(approx.x), np.asarray(exact.x), atol=envelope, rtol=0
    )


# ---------------------------------------------------------------------------
# push_stats accounting
# ---------------------------------------------------------------------------

def test_push_stats_contract(graphs):
    _, gw = graphs
    r = solve(get_algorithm("sssp", gw, source=0), engine="push")
    s = r.push_stats
    assert set(s) == {"pushed", "edges", "touched", "touched_fraction",
                      "rounds"}
    assert s["rounds"] == r.rounds
    assert 0 < s["touched"] <= gw.n
    assert s["touched_fraction"] == pytest.approx(s["touched"] / gw.n)
    assert s["pushed"] >= s["touched"]
    # sweep engines don't carry push accounting
    assert run_async_block(get_algorithm("sssp", gw, source=0),
                           bs=BS).push_stats is None


def test_run_push_shim_matches_solve(graphs):
    _, gw = graphs
    algo = get_algorithm("sssp", gw, source=0)
    r1 = run_push(algo)
    r2 = solve(algo, engine="push")
    np.testing.assert_array_equal(np.asarray(r1.x), np.asarray(r2.x))
    assert r1.rounds == r2.rounds


# ---------------------------------------------------------------------------
# option validation & unsupported semirings
# ---------------------------------------------------------------------------

def test_push_option_validation(graphs):
    _, gw = graphs
    algo = get_algorithm("sssp", gw, source=0)
    with pytest.raises(EngineOptionsError, match="per-round frontier"):
        solve(algo, engine="push", sweeps_per_call=4)
    with pytest.raises(EngineOptionsError, match="per-round frontier"):
        solve(algo, engine="push", frontier=np.ones(gw.n, bool))
    with pytest.raises(EngineOptionsError, match="inner"):
        solve(algo, engine="push", inner=2)
    with pytest.raises(EngineUnsupportedError, match="sparse acceleration"):
        solve(algo, engine="push", extrapolate_every=4)
    with pytest.raises(EngineOptionsError, match="push_threshold"):
        solve(algo, engine="auto", push_threshold=1.5)
    with pytest.raises(EngineOptionsError, match="beta"):
        solve(algo, engine="push", beta=2.0)
    with pytest.raises(EngineOptionsError, match="buckets"):
        solve(algo, engine="push", buckets=0)


def test_push_rejects_unknown_semiring():
    fake = types.SimpleNamespace(
        semiring=types.SimpleNamespace(reduce="sum", edge_op="add"),
        combine="replace",
    )
    with pytest.raises(NotImplementedError, match="push engine"):
        push_mod._kernel_semiring(fake)
    # ... and so does the router's estimator (solve(engine="auto") catches
    # this and falls back to the sweep engine)
    fake2 = types.SimpleNamespace(
        semiring=types.SimpleNamespace(reduce="min", edge_op="add"),
        combine="replace",
    )
    with pytest.raises(NotImplementedError, match="push engine"):
        push_mod._kernel_semiring(fake2)


def test_push_x_init_shape_rejected(graphs):
    _, gw = graphs
    algo = get_algorithm("sssp", gw, source=0)
    with pytest.raises(ValueError):
        run_push(algo, x_init=np.zeros(gw.n + 1, np.float32))


# ---------------------------------------------------------------------------
# out_closure / touched_vertices(closure=)
# ---------------------------------------------------------------------------

def test_out_closure_depth_semantics():
    # path 0 -> 1 -> 2 -> 3
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 3], np.int32)
    for depth, want in [(0, [0]), (1, [0, 1]), (2, [0, 1, 2]),
                        (3, [0, 1, 2, 3])]:
        mask = out_closure(src, dst, np.array([0]), 4, depth=depth)
        assert np.nonzero(mask)[0].tolist() == want
    # bool-mask seeds are accepted as-is
    seed_mask = np.array([False, True, False, False])
    mask = out_closure(src, dst, seed_mask, 4, depth=1)
    assert np.nonzero(mask)[0].tolist() == [1, 2]
    with pytest.raises(ValueError, match="bool seed mask"):
        out_closure(src, dst, np.array([True, False]), 4)
    # empty seeds stay empty at any depth
    assert not out_closure(src, dst, np.empty(0, np.int64), 4, depth=2).any()


def test_touched_vertices_closure_semantics():
    g = Graph(5, np.array([0, 1, 2, 3]), np.array([1, 2, 3, 4]))
    delta = GraphDelta(rew_src=[1], rew_dst=[2], rew_w=[2.0])
    g2 = delta.apply(g)
    assert delta.touched_vertices().tolist() == [1, 2]
    assert delta.touched_vertices(g2, closure=1).tolist() == [1, 2, 3]
    assert delta.touched_vertices(g2, closure=2).tolist() == [1, 2, 3, 4]
    with pytest.raises(ValueError, match="post-apply graph"):
        delta.touched_vertices(closure=1)


# ---------------------------------------------------------------------------
# GraphServer push absorption
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("thresh", [0.0, 1.0])
def test_server_push_absorption_resolves_in_flight(thresh, graphs):
    """push_threshold=1.0 forces the absorption path for every warm delta;
    0.0 is the plain rebuild. Both must resolve in-flight queries to the
    new graph's fixpoint (bitwise for sssp, within eps for ppr)."""
    _, gw = graphs
    srv = GraphServer(gw, slots=3, bs=BS, rounds_per_batch=2,
                      delta_mode="warm", push_threshold=thresh)
    t_ppr = srv.submit("ppr", {"seeds": [7]})
    t_sssp = srv.submit("sssp", {"source": 0})
    srv.step()
    assert t_sssp.status == "running"  # genuinely in flight when delta lands
    srv.apply_delta(random_delta(gw, frac_add=0.002, seed=5))
    srv.run()
    g2 = srv.g
    solo_sssp = run_async_block(get_algorithm("sssp", g2, source=0), bs=BS)
    np.testing.assert_array_equal(np.asarray(t_sssp.result),
                                  np.asarray(solo_sssp.x))
    solo_ppr = run_async_block(personalized_pagerank(g2, [7]), bs=BS)
    np.testing.assert_allclose(np.asarray(t_ppr.result),
                               np.asarray(solo_ppr.x), atol=1e-5, rtol=0)


def test_server_push_threshold_validation(graphs):
    _, gw = graphs
    with pytest.raises(ValueError, match="push_threshold"):
        GraphServer(gw, slots=2, push_threshold=1.5)
