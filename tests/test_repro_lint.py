"""Self-tests for the repro-lint suite (`tools.check`) — PR tentpole.

Two layers:

1. **Fixture true-positives** — each checker is fed a source/registry
   fixture with seeded violations and must report exactly the expected
   (rule, line) set: a checker that goes quiet on its own fixture is dead
   code, not a gate. The SR002 fixture is the PR 2 regression: a ``max``
   semiring registered with the *min* accumulator identity — the drift that
   once made ``max_old`` combines reduce from the wrong end of the lattice.
2. **Clean tree** — every checker runs green on the repo itself, so the CI
   gate (`python -m tools.check`) is enforceable from this commit on.
"""
import os
import subprocess
import sys
import types

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)  # `tools` lives at the repo root

from tools.check import host_sync, options_drift, pallas_resources  # noqa: E402
from tools.check import semiring_contracts as sc  # noqa: E402
from tools.check.common import (  # noqa: E402
    Finding,
    apply_pragmas,
    parse_pragmas,
)

FIX = os.path.join(ROOT, "tests", "fixtures", "repro_lint")


def _read(name):
    with open(os.path.join(FIX, name), encoding="utf-8") as fh:
        return fh.read()


# ------------------------------------------------------------- host-sync


def test_host_sync_fixture_exact_findings():
    findings = host_sync.check_source(_read("hs_fixture.py"), "hs_fixture.py")
    got = sorted((f.rule, f.line) for f in findings)
    assert got == [
        ("HS001", 13),   # float(jnp.sum(x))
        ("HS002", 14),   # x.item()
        ("HS003", 15),   # np.asarray(x)
        ("HS004", 16),   # if x:
        ("HS005", 18),   # bare jax.device_get
        ("HS006", 20),   # pragma with empty reason
    ]
    # the pragma'd device_get (line 19) and the whole multiline_pragma_covers
    # / host_only_stays_quiet bodies must stay silent
    assert not any(f.line in (19, 26, 27, 28) for f in findings)
    assert not any(f.line >= 32 for f in findings)


def test_host_sync_obs_fixture_exact_findings():
    """The observability contract is checkable: a recording call site that
    coerces a jax value into a span attr / metric observation is the HS001/
    HS002 bug class, while the audited device_get + host-scalar pattern the
    real hot paths use stays silent."""
    findings = host_sync.check_source(
        _read("obs_fixture.py"), "obs_fixture.py"
    )
    got = sorted((f.rule, f.line) for f in findings)
    assert got == [
        ("HS001", 18),   # sp.set(max_delta=float(jnp.max(deltas)))
        ("HS002", 24),   # hist.observe(state.sum().item(), ...)
    ]


def test_obs_modules_are_hot_paths():
    """src/repro/obs/*.py (and the serving stats module) are inside the
    checker's hot-path globs — the zero-sync tracing contract is enforced,
    not aspirational."""
    import fnmatch

    for rel in ("src/repro/obs/trace.py", "src/repro/obs/telemetry.py",
                "src/repro/serving/stats.py"):
        assert any(fnmatch.fnmatch(rel, g)
                   for g in host_sync.HOT_PATH_GLOBS), rel


def test_pragma_covers_multiline_expression():
    src = "\n".join([
        "import jax",
        "def f(x):",
        "    return jax.device_get(",
        "        x",
        "    )  # repro: allow-host-sync(reason on closing paren)",
    ])
    assert host_sync.check_source(src, "m.py") == []


def test_pragma_on_unrelated_line_does_not_suppress():
    src = "\n".join([
        "import jax",
        "# repro: allow-host-sync(floating pragma far away)",
        "",
        "def f(x):",
        "    return jax.device_get(x)",
    ])
    findings = host_sync.check_source(src, "m.py")
    assert [(f.rule, f.line) for f in findings] == [("HS005", 5)]


def test_jaxiness_crosses_jit_and_session_attrs():
    src = "\n".join([
        "import jax",
        "import numpy as np",
        "from functools import partial",
        "@partial(jax.jit, static_argnums=0)",
        "def _run(n, x):",
        "    return x",
        "def caller(x, fam):",
        "    out = _run(4, x)",
        "    a = float(out)",               # HS001 via jit-returned name
        "    b = np.asarray(fam.session.state)",  # HS003 via DEVICE_ATTRS
        "    return a, b",
    ])
    rules = sorted((f.rule, f.line) for f in host_sync.check_source(src, "m.py"))
    assert rules == [("HS001", 9), ("HS003", 10)]


def test_apply_pragmas_reports_unreasoned_pragma():
    src = "x = 1  # repro: allow-host-sync()\n"
    out = apply_pragmas([], parse_pragmas(src), "m.py")
    assert [(f.rule, f.line) for f in out] == [("HS006", 1)]
    assert isinstance(out[0], Finding)


def test_host_sync_clean_on_repo_hot_paths():
    assert host_sync.run(ROOT) == []


# -------------------------------------------------------------- semiring


_BIG = sc.REDUCE_IDENTITY["min"]


def _tables(**over):
    base = dict(
        kernel_semiring={("max", "min"): "max_min"},
        acc_identity={"max_min": -_BIG},
        tile_fill={"max_min": 0.0},
        delta_metric={"max_min": "linf"},
        supported={("max_min", "max_old")},
    )
    base.update(over)
    return sc.Tables(**base)


def test_sr002_pr2_max_old_min_identity_regression():
    """The PR 2 bug, reconstructed: ACC_IDENTITY for the max semiring set to
    the *min* lattice end (+BIG). The checker must name it."""
    bad = _tables(acc_identity={"max_min": _BIG})
    rules = [f.rule for f in sc.check_tables(bad)]
    assert rules == ["SR002"]
    assert "PR 2" in sc.check_tables(bad)[0].message


def test_sr001_missing_registry_entries():
    bad = _tables(delta_metric={}, supported={("ghost", "max_old")})
    rules = sorted(f.rule for f in sc.check_tables(bad))
    assert rules == ["SR001", "SR001"]  # missing DELTA_METRIC + ghost pair


def test_sr003_sr004_sr006_algorithm_contracts():
    t = _tables()
    semiring = types.SimpleNamespace
    inst = lambda red, op, comb, res: types.SimpleNamespace(  # noqa: E731
        semiring=semiring(reduce=red, edge_op=op), combine=comb, residual=res
    )
    instances = {
        "unmapped": inst("min", "mul", "replace", "linf"),      # SR003
        "unsupported": inst("max", "min", "changed", "linf"),   # SR003
        "drifted": inst("max", "min", "max_old", "l2"),         # SR004
    }
    rules = sorted(f.rule for f in sc.check_algorithm_contracts(t, instances))
    assert rules == ["SR003", "SR003", "SR004"]

    t2 = sc.Tables(
        kernel_semiring={("sum", "add"): "plus_plus"},
        acc_identity={"plus_plus": 0.0}, tile_fill={"plus_plus": 0.0},
        delta_metric={"plus_plus": "linf"},
        supported={("plus_plus", "accum")},
    )
    bad_sum = {"nonlinear": inst("sum", "add", "accum", "linf")}
    rules = sorted(f.rule for f in sc.check_algorithm_contracts(t2, bad_sum))
    assert rules == ["SR006"]  # sum-reduce but not the linear replace/mul form


def test_sr005_boundary_that_fails_to_raise_is_flagged():
    ok = sc._expect_not_implemented(
        lambda: (_ for _ in ()).throw(NotImplementedError()), "good boundary"
    )
    assert ok is None
    f = sc._expect_not_implemented(lambda: None, "silent boundary")
    assert f is not None and f.rule == "SR005"
    f = sc._expect_not_implemented(
        lambda: (_ for _ in ()).throw(KeyError("x")), "wrong exception"
    )
    assert f is not None and f.rule == "SR005"


def test_semiring_contracts_clean_on_repo_registries():
    assert sc.run(ROOT) == []


# ---------------------------------------------------------------- pallas


def _pl_budgets():
    from repro.kernels.budgets import KernelBudget

    return {
        "bad_kernel": KernelBudget(
            vmem_limit_bytes=4096, smem_limit_bytes=1024,
            points=({"bs": 64, "d": 64, "nb": 4},),
        ),
        "unresolvable_kernel": KernelBudget(
            vmem_limit_bytes=65536, smem_limit_bytes=1024,
            points=({"bs": 8},),
        ),
        "ghost_kernel": KernelBudget(
            vmem_limit_bytes=1, smem_limit_bytes=1, points=({},),
        ),
    }


def test_pallas_fixture_exact_findings():
    sites = pallas_resources.collect_sites(
        [os.path.join(FIX, "pl_fixture.py")], FIX
    )
    findings = pallas_resources.check_sites(sites, _pl_budgets())
    got = sorted((f.rule, f.path, f.line) for f in findings)
    assert got == [
        ("PL001", "pl_fixture.py", 14),  # VMEM 81920 B over the 4096 B budget
        ("PL002", "<budgets>", 0),       # ghost_kernel: dead contract
        ("PL002", "pl_fixture.py", 26),  # unbudgeted_kernel: no budget entry
        ("PL003", "pl_fixture.py", 17),  # in_spec lambda arity vs grid rank
        ("PL003", "pl_fixture.py", 18),  # out_spec 3 coords, rank-2 block
        ("PL004", "pl_fixture.py", 14),  # alias {5: 0} out of range
        ("PL005", "pl_fixture.py", 34),  # mystery_dim not in the point env
    ]


def test_pallas_footprint_model_counts_double_buffering():
    sites = pallas_resources.collect_sites(
        [os.path.join(FIX, "pl_fixture.py")], FIX
    )
    site = next(s for s in sites if s.name == "bad_kernel")
    env = {"bs": 64, "d": 64, "nb": 4, "n": 256}
    vmem, smem = pallas_resources._footprint_at(site, env)
    # scratch (64x64) + 2x in window + 2x out window, 4 B/elem
    assert vmem == 64 * 64 * 4 * 5
    assert smem == 0


def test_pallas_clean_on_repo_kernels():
    assert pallas_resources.run(ROOT) == []


def test_push_scatter_budget_entry_is_live():
    """The push kernel's budget is a live contract, not decoration: the real
    `push_scatter.py` site resolves at every declared point and passes under
    its declared budget — and an artificially tiny budget trips PL001, so
    the checker is actually evaluating this kernel's footprint."""
    import dataclasses

    from repro.kernels.budgets import KERNEL_BUDGETS

    path = os.path.join(ROOT, "src", "repro", "kernels", "push_scatter.py")
    sites = pallas_resources.collect_sites([path], os.path.join(ROOT, "src"))
    site = next(s for s in sites if s.name == "push_scatter_pallas")
    real = KERNEL_BUDGETS["push_scatter_pallas"]
    assert pallas_resources.check_sites(
        [site], {"push_scatter_pallas": real}) == []
    tiny = dataclasses.replace(real, vmem_limit_bytes=16, smem_limit_bytes=16)
    rules = sorted({f.rule for f in pallas_resources.check_sites(
        [site], {"push_scatter_pallas": tiny})})
    assert rules == ["PL001"]


def test_repo_kernel_footprints_fit_declared_budgets_with_headroom():
    """The README table inputs: every declared point resolves and lands
    under its budget (check_sites passing is the gate; this pins the
    magnitudes so a budget edit that flips the math is visible here)."""
    from repro.kernels.budgets import KERNEL_BUDGETS

    rows = pallas_resources.footprints(ROOT)
    assert set(rows) == set(KERNEL_BUDGETS)
    for name, points in rows.items():
        b = KERNEL_BUDGETS[name]
        assert len(points) == len(b.points)
        for _point, vmem, smem in points:
            assert 0 < vmem <= b.vmem_limit_bytes
            assert smem <= b.smem_limit_bytes


# --------------------------------------------------------------- options


def test_options_fixture_exact_findings():
    findings = options_drift.check_module(
        _read("od_fixture.py"), "od_fixture.py", "| `bs` | block size |"
    )
    got = sorted((f.rule, f.line) for f in findings)
    assert got == [("OD001", 12), ("OD002", 0)]
    assert all("unchecked" in f.message for f in findings)


def test_options_clean_on_repo_api():
    assert options_drift.run(ROOT) == []


# ------------------------------------------------------------ full gate


def test_cli_clean_tree_exits_zero():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.check", "--root", ROOT],
        cwd=ROOT, capture_output=True, text=True,
        env={**os.environ, "JAX_PLATFORMS": "cpu"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "repro-lint: clean" in proc.stdout


def test_budget_identity_sanity():
    # REDUCE_IDENTITY mirrors engine.algorithms.BIG exactly
    from repro.engine.algorithms import BIG

    assert sc.REDUCE_IDENTITY["min"] == float(np.float32(BIG))
    assert sc.REDUCE_IDENTITY["max"] == -float(np.float32(BIG))
    assert sc.REDUCE_IDENTITY["sum"] == 0.0
