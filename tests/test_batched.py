"""Batched multi-query execution semantics (f32[n, d] states).

The contract: a batched d-column run IS d independent scalar runs — same
final states column-for-column (bitwise on CPU: the per-round ops are
identical elementwise programs) and same per-query round counts (per-column
convergence freezing). Plus the shared pack path's padding-fill regression.
"""
import numpy as np
import pytest

from repro.engine import (
    get_algorithm,
    multi_source_sssp,
    personalized_pagerank,
    run_async_block,
    run_sync,
)
from repro.engine import harness
from repro.engine.priority import run_priority_block
from repro.graphs import generators as gen


@pytest.fixture(scope="module")
def graph():
    return gen.scrambled(gen.powerlaw_cluster(900, 4, seed=1), seed=9)


@pytest.fixture(scope="module")
def wgraph(graph):
    return gen.with_random_weights(graph, seed=2)


SEEDS = [0, 5, 17, 100, 33, 7, 250, 512]


@pytest.mark.parametrize("runner", [
    pytest.param(lambda a: run_sync(a), id="sync"),
    pytest.param(lambda a: run_async_block(a, bs=128), id="async_block"),
])
def test_batched_ppr_equals_scalar_columns(graph, runner):
    """d=8 batched PPR == 8 scalar runs, bitwise per column, with matching
    per-column round counts."""
    rb = runner(personalized_pagerank(graph, SEEDS))
    assert rb.x.shape == (graph.n, len(SEEDS))
    assert rb.converged and bool(rb.col_converged.all())
    for j, s in enumerate(SEEDS):
        rs = runner(personalized_pagerank(graph, [s]))
        assert rs.x.shape == (graph.n,)
        np.testing.assert_array_equal(
            rb.x[:, j], rs.x,
            err_msg=f"column {j} (seed {s}) differs from its scalar run",
        )
        assert int(rb.col_rounds[j]) == rs.rounds, (
            f"column {j}: batched rounds {int(rb.col_rounds[j])} != "
            f"scalar rounds {rs.rounds}"
        )
    # the batch executes exactly as long as its slowest query
    assert rb.rounds == int(rb.col_rounds.max())


def test_batched_ppr_matches_exact(graph):
    algo = personalized_pagerank(graph, SEEDS)
    r = run_async_block(algo, bs=128)
    np.testing.assert_allclose(r.x, algo.exact(), atol=2e-5, rtol=1e-4)


def test_multi_source_sssp_equals_scalar_sources(wgraph):
    sources = [0, 9, 77, 300]
    rb = run_async_block(multi_source_sssp(wgraph, sources), bs=128)
    assert rb.converged
    np.testing.assert_allclose(
        rb.x, multi_source_sssp(wgraph, sources).exact(), atol=2e-5, rtol=1e-4
    )
    for j, s in enumerate(sources):
        rs = run_async_block(multi_source_sssp(wgraph, [s]), bs=128)
        np.testing.assert_array_equal(rb.x[:, j], rs.x)
        assert int(rb.col_rounds[j]) == rs.rounds


def test_scalar_d1_contract_unchanged(graph):
    """d=1 keeps the legacy RunResult shape: 1-D x, scalar rounds."""
    r = run_sync(get_algorithm("pagerank", graph))
    assert r.x.ndim == 1 and r.d == 1
    assert r.col_rounds.shape == (1,) and int(r.col_rounds[0]) == r.rounds


def test_pallas_backend_parity(graph):
    """run_async_block(backend='pallas') drives the fused gs_sweep kernel
    through the same convergence harness as the jax backend."""
    algo = personalized_pagerank(graph, [0, 5, 17, 99])
    r_jax = run_async_block(algo, bs=64)
    r_pal = run_async_block(algo, bs=64, backend="pallas", max_iters=300)
    np.testing.assert_allclose(r_pal.x, r_jax.x, atol=1e-4, rtol=1e-4)
    np.testing.assert_array_equal(r_pal.col_rounds, r_jax.col_rounds)


def test_priority_engine_batched(graph):
    algo = personalized_pagerank(graph, [0, 5, 17, 99])
    r = run_priority_block(algo, bs=64)
    assert r.converged
    np.testing.assert_allclose(r.x, algo.exact(), atol=2e-4, rtol=1e-3)


def test_pack_c_fill_uses_reduce_identity(wgraph):
    """Regression: the shared pack path must pad `c` with the reduce identity
    for min/max semirings (a 0.0 pad is an absorbing element under min —
    under `min_old` combine it would drag padding vertices to 0 and, were a
    padding row ever unpinned, corrupt real states)."""
    algo = get_algorithm("sssp", wgraph)  # min semiring, combine="min_old"
    bs = 128
    assert algo.n % bs != 0, "fixture must exercise real padding"
    be, x0, c, fixed, npad = harness.pack(algo, bs)
    assert npad > algo.n
    ident = algo.semiring.identity
    assert np.all(c[algo.n:] == np.float32(ident))
    assert np.all(fixed[algo.n:])
    assert np.all(x0[algo.n:] == np.float32(ident))
    # and "replace" (sum) algorithms keep the additive 0.0 pad
    algo2 = get_algorithm("pagerank", wgraph)
    _, _, c2, _, _ = harness.pack(algo2, bs)
    assert np.all(c2[algo2.n:] == 0.0)


def test_min_semiring_unaligned_size_end_to_end(wgraph):
    """min-semiring graph whose size is not a multiple of bs must still hit
    the exact fixpoint through the padded engines (both backends)."""
    assert wgraph.n % 128 != 0
    algo = get_algorithm("sssp", wgraph)
    for backend in ("jax", "pallas"):
        r = run_async_block(algo, bs=128, backend=backend, max_iters=300)
        assert r.converged, backend
        np.testing.assert_allclose(
            r.x, algo.exact(), atol=2e-5, rtol=1e-4, err_msg=backend
        )


def test_x_init_resume_batched(graph):
    """Macro-stepped batched runs (checkpoint/resume path) reach the same
    fixpoint as one uninterrupted run."""
    algo = personalized_pagerank(graph, [3, 44, 500])
    full = run_async_block(algo, bs=128)
    state = algo.x0
    for _ in range(100):
        r = run_async_block(algo, bs=128, max_iters=4, x_init=state)
        state = r.x
        if r.converged:
            break
    assert r.converged
    np.testing.assert_allclose(state, full.x, atol=1e-5, rtol=1e-5)
