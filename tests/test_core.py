"""Tests for the paper's core: the metric M(.), GoGraph, and baselines."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graphs import generators as gen
from repro.graphs.graph import Graph
from repro.core import metric, baselines
from repro.core.gograph import GoGraphConfig, gograph_order
from repro.core import partition as part


def _random_graph(n, avg_deg, seed):
    return gen.erdos_renyi(n, avg_deg, seed=seed)


def test_metric_simple():
    # a->b->c in id order: both edges positive
    g = Graph(3, np.array([0, 1]), np.array([1, 2]))
    assert metric.metric_m(g, np.array([0, 1, 2])) == 2
    assert metric.metric_m(g, np.array([2, 1, 0])) == 0
    assert metric.metric_m(g, np.array([1, 0, 2])) == 1  # b,a,c: only b->c


def test_metric_jax_matches_numpy():
    import jax.numpy as jnp

    g = _random_graph(200, 4.0, 0)
    rank = np.random.default_rng(1).permutation(g.n)
    m1 = metric.metric_m(g, rank)
    m2 = int(metric.metric_m_jax(jnp.asarray(g.src), jnp.asarray(g.dst),
                                 jnp.asarray(rank)))
    assert m1 == m2


def test_paper_fig3_example():
    """The worked example of paper Fig. 3: GoGraph beats the hub-first order."""
    # graph of Fig. 3a: a=0,b=1,c=2,d=3,e=4,f=5,g=6,h=7
    edges = [(1, 0), (7, 0), (0, 2), (2, 1), (3, 0), (0, 4), (4, 1), (3, 4),
             (0, 6), (6, 1), (5, 0), (6, 5), (1, 5), (0, 5)]
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    g = Graph(8, src, dst)
    rank = gograph_order(g, GoGraphConfig(hd_fraction=0.25, min_n_for_hd=1,
                                          max_subgraph=8))
    m_gg = metric.metric_m(g, rank)
    # the paper's O^1_V (no HD extraction) achieves 10; GoGraph should do
    # at least as well as |E|/2 and at least as well as the default order
    assert m_gg >= g.m / 2
    assert m_gg >= metric.metric_m(g, baselines.default_order(g))


@given(st.integers(50, 400), st.floats(1.0, 6.0), st.integers(0, 5))
@settings(max_examples=15, deadline=None)
def test_gograph_theorem2(n, avg_deg, seed):
    """Theorem 2: M(GoGraph order) >= |E|/2, and rank is a permutation."""
    g = _random_graph(n, avg_deg, seed)
    if g.m == 0:
        return
    rank = gograph_order(g)
    assert sorted(rank.tolist()) == list(range(g.n))
    assert metric.metric_m(g, rank) >= g.m / 2


def test_gograph_beats_baselines_on_clustered_graph():
    g = gen.scrambled(gen.powerlaw_cluster(2000, 4, seed=1), seed=9)
    ranks = {name: fn(g) for name, fn in baselines.all_reorderers().items()}
    ms = {name: metric.positive_edge_fraction(g, r) for name, r in ranks.items()}
    assert ms["GoGraph"] == max(ms.values())
    assert ms["GoGraph"] > 0.65  # paper Table II: 0.76 on CP
    # every baseline produces a permutation
    for name, r in ranks.items():
        assert sorted(r.tolist()) == list(range(g.n)), name


@given(st.integers(1, 40), st.integers(0, 50))
@settings(max_examples=40, deadline=None)
def test_scan_best_gap_matches_sequential_reference(k, seed):
    """The vectorized GetOptVal gap scan must reproduce the paper's
    sequential loop bitwise: same running f64 pe, same strict-improvement
    ("paper line 18") tie-breaking, same best gap index."""
    from repro.core.gograph import _scan_best_gap

    rng = np.random.default_rng(seed)
    # signed per-neighbor deltas incl. exact ties and zeros, plus a head pe
    delta_per = rng.choice([-2.0, -1.0, -0.5, 0.0, 0.5, 1.0, 2.0], size=k)
    pe0 = float(rng.choice([0.0, 0.5, 1.0, 3.0]))

    pe = pe0
    best_pe = pe
    best_idx = -1
    for i in range(k):
        pe += delta_per[i]
        if pe > best_pe:  # strict improvement
            best_pe = pe
            best_idx = i

    assert _scan_best_gap(pe0, delta_per) == best_idx


def test_inserter_bitwise_identical_to_sequential_scan():
    """End-to-end pin for the vectorized GetOptVal scan: replaying identical
    insertion sequences through the current `_Inserter` and through a
    reference inserter whose scan is the original sequential loop must
    produce bitwise-identical val arrays (hence identical orders)."""
    import repro.core.gograph as gg

    class _ReferenceInserter(gg._Inserter):
        pass

    def _sequential_scan(pe_head, delta_per):
        pe = pe_head
        best_pe, best_idx = pe, -1
        for i in range(len(delta_per)):
            pe += delta_per[i]
            if pe > best_pe:  # strict improvement (paper line 18)
                best_pe, best_idx = pe, i
        return best_idx

    g = gen.scrambled(gen.powerlaw_cluster(400, 4, seed=7), seed=2)
    gw = gen.with_random_weights(g, seed=3)
    csc_indptr, csc_src, csc_eid = gw.csc()
    csr_indptr, csr_dst, csr_eid = gw.csr()

    ins = gg._Inserter(g.n)
    ref = _ReferenceInserter(g.n)
    orig = gg._scan_best_gap
    rng = np.random.default_rng(0)
    for v in rng.permutation(g.n):
        inn = csc_src[csc_indptr[v]:csc_indptr[v + 1]].astype(np.int64)
        win = gw.weights[csc_eid[csc_indptr[v]:csc_indptr[v + 1]]]
        outn = csr_dst[csr_indptr[v]:csr_indptr[v + 1]].astype(np.int64)
        wout = gw.weights[csr_eid[csr_indptr[v]:csr_indptr[v + 1]]]
        v1 = ins.insert(int(v), inn, win, outn, wout)
        gg._scan_best_gap = _sequential_scan
        try:
            v2 = ref.insert(int(v), inn, win, outn, wout)
        finally:
            gg._scan_best_gap = orig
        assert v1 == v2, v
    np.testing.assert_array_equal(ins.val, ref.val)


def test_gograph_deterministic():
    g = gen.powerlaw_cluster(500, 3, seed=2)
    r1 = gograph_order(g)
    r2 = gograph_order(g)
    assert np.array_equal(r1, r2)


def test_gograph_phases():
    g = gen.scrambled(gen.powerlaw_cluster(1500, 4, seed=3), seed=1)
    rank, info = gograph_order(g, return_info=True)
    assert len(info["hd"]) == int(round(g.n * 0.002))
    assert len(info["hd"]) + len(info["iso"]) + len(info["core"]) == g.n
    assert "labels" in info


def test_gograph_edge_cases():
    # empty graph
    g0 = Graph(0, np.empty(0, np.int32), np.empty(0, np.int32))
    assert len(gograph_order(g0)) == 0
    # no edges
    g1 = Graph(5, np.empty(0, np.int32), np.empty(0, np.int32))
    assert sorted(gograph_order(g1).tolist()) == list(range(5))
    # single chain
    g2 = Graph(4, np.array([0, 1, 2]), np.array([1, 2, 3]))
    r = gograph_order(g2, GoGraphConfig(min_n_for_hd=1000))
    assert metric.metric_m(g2, r) == 3  # chain is perfectly orderable


def test_block_fresh_fraction():
    g = Graph(4, np.array([0, 1, 2]), np.array([1, 2, 3]))
    rank = np.arange(4)
    f = metric.block_fresh_fraction(g, rank, bs=2)
    # blocks {0,1},{2,3}: edge 1->2 crosses (fresh), 0->1 and 2->3 intra
    assert f["fresh"] == pytest.approx(1 / 3)
    assert f["intra"] == pytest.approx(2 / 3)


def test_partitioners():
    g = gen.community_graph(600, 6, avg_degree=8, p_intra=0.9, seed=4)
    for method in ("labelprop", "louvain", "fennel", "bfs"):
        labels = part.partition(g, method=method, max_size=200)
        assert labels.shape == (g.n,)
        assert np.bincount(labels).max() <= 200
    # labelprop should recover strong communities reasonably well: most
    # edges intra-community
    labels = part.label_propagation(g, seed=0)
    intra = np.mean(labels[g.src] == labels[g.dst])
    assert intra > 0.5


def test_enforce_max_size():
    g = gen.erdos_renyi(300, 3.0, seed=5)
    labels = np.zeros(g.n, dtype=np.int64)  # everything in one part
    fixed = part.enforce_max_size(g, labels, max_size=50)
    assert np.bincount(fixed).max() <= 50
