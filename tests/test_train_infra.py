"""Training-infrastructure tests: optimizer, loop, microbatching, ZeRO,
gradient compression, checkpointing, fault tolerance, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optim
from repro.data.tokens import TokenDataset, TokenDatasetConfig
from repro.ckpt.manager import CheckpointManager
from repro.runtime.fault import FaultTolerantRunner, StragglerMonitor, PreemptionGuard
from tests.util import run_with_devices


# ------------------------------------------------------------------ optimizer

def test_adamw_decreases_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = optim.init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = optim.adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_lr_schedule():
    cfg = optim.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(optim.lr_at(cfg, jnp.int32(0))) == 0.0
    assert float(optim.lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(optim.lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(grads, 1.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


# ----------------------------------------------------------------- data

def test_dataset_deterministic_and_restartable():
    cfg = TokenDatasetConfig(vocab=100, seq_len=16, global_batch=4, seed=3)
    ds1 = TokenDataset(cfg)
    ds2 = TokenDataset(cfg)
    b5a = ds1(5)
    _ = ds1(6)
    b5b = ds2(5)  # a fresh pipeline resuming at step 5 sees the same batch
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert not np.array_equal(ds1(7)["tokens"], ds1(8)["tokens"])


def test_dataset_learnable_structure():
    cfg = TokenDatasetConfig(vocab=50, seq_len=64, global_batch=8, seed=0,
                             structure=1.0)
    ds = TokenDataset(cfg)
    b = ds(0)
    succ = ds.successor[b["tokens"]]
    match = (succ == b["labels"]).mean()
    assert match > 0.99  # fully structured stream


# ----------------------------------------------------------------- ckpt

def test_ckpt_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    params = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
              "b": np.float32(1.5)}
    for step in (1, 2, 3):
        mgr.save(step, params)
    assert mgr.all_steps() == [2, 3]
    template = {"params": jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), params)}
    tree, manifest = mgr.restore(template=template)
    assert manifest["step"] == 3
    np.testing.assert_array_equal(tree["params"]["w"], params["w"])


def test_ckpt_atomic_tmp_cleanup(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    # simulate a crashed write
    os.makedirs(tmp_path / "step_00000009.tmp")
    mgr.save(1, {"w": np.ones(3, np.float32)})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert mgr.all_steps() == [1]


def test_ckpt_elastic_remesh_subprocess():
    """Save on a (4,2) mesh, restore onto (2,4) — elastic re-mesh."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt.manager import CheckpointManager
from repro.runtime.jax_compat import make_mesh
d = tempfile.mkdtemp()
mesh1 = make_mesh((4, 2), ('data', 'model'))
x = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                   NamedSharding(mesh1, P('data', 'model')))
mgr = CheckpointManager(d)
mgr.save(7, {'w': x})
mesh2 = make_mesh((2, 4), ('data', 'model'))
template = {'params': {'w': jax.ShapeDtypeStruct((8, 8), np.float32)}}
shardings = {'params': {'w': NamedSharding(mesh2, P('data', 'model'))}}
tree, man = mgr.restore(template=template, shardings=shardings)
w = tree['params']['w']
assert w.sharding.mesh.shape['model'] == 4
np.testing.assert_array_equal(np.asarray(w), np.arange(64).reshape(8,8))
print('elastic ok')
""", n_devices=8)


# ----------------------------------------------------------------- fault

def test_fault_tolerant_runner_recovers():
    saves = {}
    state = {"v": 0}
    injected = {"done": False}

    def step_fn(st, step):
        if step == 5 and not injected["done"]:
            injected["done"] = True
            raise RuntimeError("injected node failure")
        return {"v": st["v"] + 1}

    def save_fn(step, st):
        saves[step] = dict(st)

    def restore_fn():
        step = max(saves)
        return dict(saves[step]), step

    runner = FaultTolerantRunner(step_fn, save_fn, restore_fn, ckpt_every=2,
                                 max_failures=2)
    final, step = runner.run(state, steps=10)
    assert step == 10
    assert final["v"] == 10  # no lost or duplicated steps
    assert runner.failures == 1
    assert any("restored" in line for line in runner.log)


def test_fault_runner_gives_up_after_max_failures():
    def step_fn(st, step):
        raise RuntimeError("permanent failure")

    runner = FaultTolerantRunner(step_fn, lambda s, st: None,
                                 lambda: ({}, 0), max_failures=2)
    with pytest.raises(RuntimeError):
        runner.run({}, steps=3)
    assert runner.failures == 3


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0)
    for i in range(10):
        mon.observe(i, 0.1)
    mon.observe(10, 0.5)  # 5x median
    assert len(mon.events) == 1
    assert mon.events[0].ratio == pytest.approx(5.0, rel=0.01)


def test_preemption_guard_flag():
    import signal

    guard = PreemptionGuard(install=True)
    try:
        os.kill(os.getpid(), signal.SIGTERM)
        assert guard.preempted
    finally:
        guard.restore()


# ----------------------------------------------------------- train step (SPMD)

def test_train_step_loss_decreases_subprocess():
    run_with_devices("""
import jax, numpy as np
from repro.configs import get_reduced
from repro.models.model import build_model
from repro.launch.mesh import make_debug_mesh
from repro.sharding.rules import default_rules
from repro.train.loop import TrainConfig, make_train_step, init_train_state
from repro.train import optim
from repro.data.tokens import TokenDataset, TokenDatasetConfig
from repro.runtime.jax_compat import set_mesh

cfg = get_reduced('olmo-1b')
model = build_model(cfg)
mesh = make_debug_mesh(n_data=4, n_model=2)
rules = default_rules(mesh)
tcfg = TrainConfig(opt=optim.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60))
step_fn, shardings = make_train_step(model, mesh, rules, tcfg)
params, opt_state = init_train_state(model, mesh, shardings)
ds = TokenDataset(TokenDatasetConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0, structure=1.0))
losses = []
with set_mesh(mesh):
    for step in range(40):
        params, opt_state, m = step_fn(params, opt_state, ds(step))
        losses.append(float(m['loss']))
assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])
print('loss', losses[0], '->', losses[-1])
""", n_devices=8, timeout=900)


def test_microbatch_equivalence_subprocess():
    """grad accumulation over 4 microbatches == single big batch update."""
    run_with_devices("""
import jax, numpy as np
from repro.configs import get_reduced
from repro.models.model import build_model
from repro.launch.mesh import make_debug_mesh
from repro.sharding.rules import default_rules
from repro.train.loop import TrainConfig, make_train_step, init_train_state
from repro.data.tokens import TokenDataset, TokenDatasetConfig
from repro.runtime.jax_compat import set_mesh

cfg = get_reduced('deepseek-7b')
model = build_model(cfg)
mesh = make_debug_mesh(n_data=2, n_model=2)
rules = default_rules(mesh)
ds = TokenDataset(TokenDatasetConfig(vocab=cfg.vocab, seq_len=16, global_batch=8, seed=0))
batch = ds(0)
outs = {}
for nm in (1, 4):
    tcfg = TrainConfig(microbatches=nm)
    step_fn, sh = make_train_step(model, mesh, rules, tcfg)
    params, opt = init_train_state(model, mesh, sh, seed=0)
    with set_mesh(mesh):
        p, o, m = step_fn(params, opt, batch)
    outs[nm] = (jax.tree.leaves(p)[0], float(m['loss']))
np.testing.assert_allclose(np.asarray(outs[1][0]), np.asarray(outs[4][0]), atol=2e-5)
assert abs(outs[1][1] - outs[4][1]) < 1e-4
print('microbatch equivalence ok')
""", n_devices=4, timeout=900)


def test_zero1_shardings_subprocess():
    run_with_devices("""
import jax, numpy as np
from repro.configs import get_reduced
from repro.models.model import build_model
from repro.launch.mesh import make_debug_mesh
from repro.sharding.rules import default_rules
from repro.train.loop import TrainConfig, make_train_step, init_train_state

cfg = get_reduced('olmo-1b')
model = build_model(cfg)
mesh = make_debug_mesh(n_data=4, n_model=2)
rules = default_rules(mesh)
step_fn, sh = make_train_step(model, mesh, rules, TrainConfig(zero1=True))
# at least one optimizer-state leaf must be sharded over the data axis
import jax.tree_util as jtu
data_sharded = 0
for ns in jax.tree.leaves(sh['opt']['m']):
    spec = ns.spec
    flat = [a for e in spec if e for a in ((e,) if isinstance(e, str) else e)]
    if 'data' in flat: data_sharded += 1
assert data_sharded > 0
print('zero1 shards', data_sharded, 'leaves over data')
""", n_devices=8)


def test_grad_compression_subprocess():
    """int8 psum matches exact mean within quantization error; error feedback
    drives the accumulated bias to ~0 over repeated steps."""
    run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.train.grad_compress import compressed_psum_tree, init_error_tree
from repro.runtime.jax_compat import make_mesh, shard_map

mesh = make_mesh((8,), ('data',))
g = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)).astype(np.float32))

def f(gl, err):
    mean, err = compressed_psum_tree({'g': gl}, ('data',), {'g': err}, 8)
    return mean['g'], err

fm = jax.jit(shard_map(f, mesh, in_specs=(P('data'), P('data')),
                       out_specs=(P(None), P('data')), check_vma=False))
err = jnp.zeros((8, 64), jnp.float32)[0:1].repeat(8, 0) * 0
exact = np.asarray(g).mean(axis=0)
total_err = np.zeros(64, np.float32)
approx, err = fm(g, jnp.zeros((8, 64), jnp.float32))
q_err = np.abs(np.asarray(approx)[0] - exact).max()
scale = np.abs(np.asarray(g)).max() / 127
assert q_err < 2 * scale, (q_err, scale)
# error feedback: summed carried error equals what was left out
print('quant err', q_err, 'scale', scale)
""", n_devices=8)
